"""Figure 11: on-disk index variants.

Part 1 (simulated, paper §6.5): DiskANN-style index — smaller RAM
footprint per partition (3.6 GB of PQ codes + cache) but costlier
per-partition loading (index deserialization + disk I/O).  RAGDoll's
profiler re-balances and wins (paper: 890s vs 1236s flat; vLLMRAG
slightly degrades 2427 vs 2331).

Part 2 (real I/O): exact-vs-IVF recall/latency sweep on a synthetic
clustered corpus with every partition spilled to disk — measures how the
``nprobe`` knob converts cluster pruning into partitions *not loaded*
(the dominant cost, §4.4) and what recall@k it costs.

Part 3 (real I/O): sharded-vs-single-host rows — the same on-disk corpus
searched through ``ShardedIVFStore`` at shard counts {1, 2, 4}.  At
equal ``nprobe`` the sharded merge is bit-identical to the single-host
sweep, so recall_vs_single must be exactly 1.0 (CI-asserted); the rows
also report per-shard load counts, i.e. how the disk work spreads.

Part 4 (real I/O): Zipf hot/cold rows — skewed query traffic over the
same on-disk corpus, cold (host tier only) vs hot (device-resident
``HotPartitionSet`` funded by the ``PlacementOptimizer.market`` split of
ONE device-byte pool shared with KV pages).  The hot sweep is
bit-identical to the cold one (recall_vs_single must be exactly 1.0)
while the hottest partitions stop hitting disk, collapsing loads and
load_seconds at the SAME total device budget (market invariant
CI-asserted).
"""
from __future__ import annotations

import tempfile
import time
from dataclasses import replace
from typing import Optional

import numpy as np

from benchmarks.common import (GB, PF_HIGH, cost_model, optimizer_factory,
                               timed, workload)
from repro.core.costmodel import CostModel, ModelProfile
from repro.core.placement import Placement, PlacementOptimizer
from repro.configs import get_config
from repro.retrieval.cache import HotPartitionSet
from repro.retrieval.streamer import PartitionStreamer
from repro.retrieval.synthetic import (ArrayEmbedder, blob_corpus,
                                       perturb_queries, zipf_queries)
from repro.retrieval.vectorstore import SearchStats, VectorStore
from repro.serving.baselines import run_suite
from repro.serving.request import latency_table


def ivf_sweep(num_partitions: int = 32, n: int = 4096, dim: int = 64,
              n_queries: int = 8, top_k: int = 10, seed: int = 0):
    """Returns rows comparing the exact all-partition sweep against IVF
    pruning at several ``nprobe`` settings, all partitions on disk."""
    rows = []
    vecs = blob_corpus(n, dim, clusters=num_partitions, seed=seed)
    emb = ArrayEmbedder(vecs)
    q = perturb_queries(vecs, n_queries, seed=seed + 1)

    with tempfile.TemporaryDirectory() as root:
        store = VectorStore.build([str(i) for i in range(n)], emb,
                                  num_partitions=num_partitions, root=root,
                                  seed=seed)
        for pid in list(store.partitions):
            store.spill(pid)
        streamer = PartitionStreamer(store)

        def run_once(nprobe):
            stats = SearchStats()
            t0 = time.perf_counter()
            _, ids = store.search(q, top_k, nprobe=nprobe,
                                  streamer=streamer, stats=stats)
            return ids, time.perf_counter() - t0, stats

        # untimed warmup: compile every per-partition top-k shape + the
        # merge kernel so the timed baseline measures I/O+search, not JIT
        run_once(None)
        exact_ids, exact_t, exact_stats = run_once(None)
        rows.append(("fig11/ivf/exact", exact_t * 1e6,
                     f"loads={exact_stats.partitions_loaded} recall=1.000"))
        for nprobe in (1, num_partitions // 8, num_partitions // 4,
                       num_partitions // 2):
            ids, t, stats = run_once(nprobe)
            recall = np.mean([
                len(set(a) & set(b)) / top_k
                for a, b in zip(ids, exact_ids)])
            rows.append((
                f"fig11/ivf/nprobe{nprobe}", t * 1e6,
                f"loads={stats.partitions_loaded} recall={recall:.3f} "
                f"speedup={exact_t / max(t, 1e-9):.1f}x"))
        streamer.close()
    return rows


def sharded_sweep(num_partitions: int = 16, n: int = 4096, dim: int = 64,
                  n_queries: int = 8, top_k: int = 10,
                  shard_counts=(1, 2, 4), nprobe: Optional[int] = None,
                  seed: int = 0):
    """Sharded-vs-single-host rows (real disk I/O): recall_vs_single is
    the fraction of single-host top-k ids the sharded merge reproduces at
    equal ``nprobe`` — 1.0 by construction (bit-identical merge), which
    the CI smoke asserts so the shard/probe/merge contract cannot rot."""
    from repro.retrieval.distributed import ShardedIVFStore

    nprobe = nprobe if nprobe is not None else max(num_partitions // 4, 1)
    rows = []
    vecs = blob_corpus(n, dim, clusters=num_partitions, seed=seed)
    emb = ArrayEmbedder(vecs)
    q = perturb_queries(vecs, n_queries, seed=seed + 1)
    with tempfile.TemporaryDirectory() as root:
        store = VectorStore.build([str(i) for i in range(n)], emb,
                                  num_partitions=num_partitions, root=root,
                                  seed=seed)
        for pid in list(store.partitions):
            store.spill(pid)
        # untimed single-host warmup compiles every kernel shape
        store.search(q, top_k, nprobe=nprobe)
        t0 = time.perf_counter()
        _, single_ids = store.search(q, top_k, nprobe=nprobe)
        single_t = time.perf_counter() - t0
        rows.append((f"fig11/sharded/single_host", single_t * 1e6,
                     f"nprobe={nprobe} recall_vs_single=1.000"))
        for s_count in shard_counts:
            sharded = ShardedIVFStore(store, s_count)
            # untimed warmup compiles this shard count's fuse shapes, so
            # the timed row measures I/O+search, not JIT (same discipline
            # as ivf_sweep's exact baseline)
            sharded.search(q, top_k, nprobe=nprobe)
            stats = SearchStats()
            t0 = time.perf_counter()
            _, ids = sharded.search(q, top_k, nprobe=nprobe, stats=stats)
            dt = time.perf_counter() - t0
            sharded.close()
            recall = np.mean([
                len(set(a[a >= 0]) & set(b[b >= 0]))
                / max(len(set(b[b >= 0])), 1)
                for a, b in zip(ids, single_ids)])
            rows.append((
                f"fig11/sharded/shards{s_count}", dt * 1e6,
                f"nprobe={nprobe} recall_vs_single={recall:.3f} "
                f"loads={stats.partitions_loaded}"))
    return rows


def zipf_sweep(num_partitions: int = 16, n: int = 4096, dim: int = 64,
               n_queries: int = 8, batches: int = 6, top_k: int = 10,
               alpha: float = 1.5, nprobe: Optional[int] = None,
               seed: int = 0):
    """Zipf hot/cold-tier rows (real disk I/O): the same skewed query
    batches run cold (every probed partition loaded from disk, released
    after) and hot (market-funded ``HotPartitionSet`` retargeted after
    each batch from decayed hit counts).  The hot sweep scores promoted
    partitions on device with the same kernel on the same float32 bits,
    so recall_vs_single is exactly 1.0 while loads/load_seconds drop —
    the §4.4 partition-loading bottleneck bought out of the single
    device-byte pool."""
    nprobe = nprobe if nprobe is not None else max(num_partitions // 4, 1)
    rows = []
    vecs = blob_corpus(n, dim, clusters=num_partitions, seed=seed)
    emb = ArrayEmbedder(vecs)
    with tempfile.TemporaryDirectory() as root:
        store = VectorStore.build([str(i) for i in range(n)], emb,
                                  num_partitions=num_partitions, root=root,
                                  seed=seed)
        # popularity rank = partition id (zipf_queries draws group r with
        # p ∝ 1/r^alpha), so the hot set should converge on the low pids
        groups = [store.partitions[pid].doc_ids
                  for pid in sorted(store.partitions)]
        qs = [zipf_queries(vecs, groups, n_queries, alpha=alpha,
                           seed=seed + 7 + b) for b in range(batches)]
        for pid in list(store.partitions):
            store.spill(pid)
        # untimed warmup compiles every per-partition top-k shape + merge
        store.search(qs[0], top_k, nprobe=nprobe)

        cold_stats = SearchStats()
        cold_ids = []
        t0 = time.perf_counter()
        for q in qs:
            _, ids = store.search(q, top_k, nprobe=nprobe, stats=cold_stats)
            cold_ids.append(ids)
        cold_t = time.perf_counter() - t0
        rows.append((
            "fig11/zipf/cold", cold_t * 1e6,
            f"alpha={alpha} nprobe={nprobe} "
            f"loads={cold_stats.partitions_loaded} "
            f"load_seconds={cold_stats.load_seconds:.4f}"))

        # market sizing: a reduced-KV model and a slow disk make partition
        # loads the dominant retrieval cost, so the optimizer funds a hot
        # tier out of the pool instead of spending every byte on KV pages.
        # The CostModel is honest to the store actually being swept.
        mp = ModelProfile.from_config(
            get_config("llama3-8b").reduced(num_layers=8))
        hw = replace(PF_HIGH, disk_read_bw=1e6)
        cm = CostModel(hw, mp, partition_bytes=float(store.partition_bytes()),
                       num_partitions=store.num_partitions, db_dim=dim,
                       chunks_per_partition=n / store.num_partitions,
                       partition_mem_overhead=1.0)
        opt = PlacementOptimizer(cm, avg_ctx_len=48, avg_out_len=16)
        placement = opt.project(
            Placement(1.0, 0.0, 1.0, 0.0, 0, 8, nprobe=nprobe))
        hot = HotPartitionSet(store)
        hot_stats = SearchStats()
        recalls = []
        market_ok = True
        split = None
        t0 = time.perf_counter()
        for q, ids_cold in zip(qs, cold_ids):
            _, ids = store.search(q, top_k, nprobe=nprobe, stats=hot_stats,
                                  hot=hot)
            recalls.append(float(np.array_equal(ids, ids_cold)))
            split = opt.market(placement, partition_heat=hot_stats.heat())
            hot.retarget(split.hot_bytes, hot_stats.hot_ranking())
            market_ok &= (split.kv_page_budget * split.page_bytes
                          + split.hot_bytes <= split.total_bytes + 1e-6)
            market_ok &= hot.device_bytes() <= split.hot_bytes
            hot_stats.decay()
        hot_t = time.perf_counter() - t0
        rows.append((
            "fig11/zipf/hot", hot_t * 1e6,
            f"alpha={alpha} nprobe={nprobe} "
            f"loads={hot_stats.partitions_loaded} "
            f"load_seconds={hot_stats.load_seconds:.4f} "
            f"hot_hits={hot_stats.hot_hits} hot_parts={len(hot)} "
            f"hot_bytes={hot.device_bytes()} "
            f"kv_pages={split.kv_page_budget} "
            f"recall_vs_single={np.mean(recalls):.3f} "
            f"market_ok={int(market_ok)}"))
        hot.clear()
    return rows


def run(full: bool = False):
    rows = []
    arr = workload(full)
    mp = ModelProfile.from_config(get_config("llama3-70b"))
    variants = {
        # flat index: 8 GB resident footprint, plain load
        "flat": CostModel(PF_HIGH, mp, partition_bytes=8 * GB,
                          num_partitions=32),
        # DiskANN: 3.6 GB resident (PQ codes), load 1.3x costlier per byte
        # of the ORIGINAL partition (index init overhead, paper section 6.5)
        "diskann": CostModel(PF_HIGH, mp, partition_bytes=8 * GB,
                             num_partitions=32,
                             partition_mem_overhead=3.6 / 8.0,
                             partition_load_overhead=1.3),
    }
    lat = {}
    for name, cm in variants.items():
        res, us = timed(lambda: run_suite(
            cm, optimizer_factory(cm), arr,
            modes=("ragdoll", "serial_vllm")))
        for mode, r in res.items():
            t = latency_table(r.requests)
            lat[(name, mode)] = t["avg_latency"]
            rows.append((f"fig11/{name}/{mode}", us / max(t["n"], 1) / 2,
                         f"avg={t['avg_latency']:.0f}s"))
    rows.append((
        "fig11/diskann_effect", 0.0,
        f"ragdoll {lat[('flat', 'ragdoll')]:.0f}->"
        f"{lat[('diskann', 'ragdoll')]:.0f}s "
        f"(paper 1236->890) vllm {lat[('flat', 'serial_vllm')]:.0f}->"
        f"{lat[('diskann', 'serial_vllm')]:.0f}s (paper 2331->2427)"))
    rows.extend(ivf_sweep(n=8192 if full else 4096))
    rows.extend(sharded_sweep(n=8192 if full else 4096))
    rows.extend(zipf_sweep(n=8192 if full else 4096))
    return rows
