"""Figure 11: DiskANN-style on-disk index — smaller RAM footprint per
partition (3.6 GB of PQ codes + cache) but costlier per-partition loading
(index deserialization + disk I/O). RAGDoll's profiler re-balances and
wins (paper: 890s vs 1236s flat; vLLMRAG slightly degrades 2427 vs 2331)."""
from __future__ import annotations

from benchmarks.common import (GB, PF_HIGH, cost_model, optimizer_factory,
                               timed, workload)
from repro.core.costmodel import CostModel, ModelProfile
from repro.configs import get_config
from repro.serving.baselines import run_suite
from repro.serving.request import latency_table


def run(full: bool = False):
    rows = []
    arr = workload(full)
    mp = ModelProfile.from_config(get_config("llama3-70b"))
    variants = {
        # flat index: 8 GB resident footprint, plain load
        "flat": CostModel(PF_HIGH, mp, partition_bytes=8 * GB,
                          num_partitions=32),
        # DiskANN: 3.6 GB resident (PQ codes), load 1.3x costlier per byte
        # of the ORIGINAL partition (index init overhead, paper section 6.5)
        "diskann": CostModel(PF_HIGH, mp, partition_bytes=8 * GB,
                             num_partitions=32,
                             partition_mem_overhead=3.6 / 8.0,
                             partition_load_overhead=1.3),
    }
    lat = {}
    for name, cm in variants.items():
        res, us = timed(lambda: run_suite(
            cm, optimizer_factory(cm), arr,
            modes=("ragdoll", "serial_vllm")))
        for mode, r in res.items():
            t = latency_table(r.requests)
            lat[(name, mode)] = t["avg_latency"]
            rows.append((f"fig11/{name}/{mode}", us / max(t["n"], 1) / 2,
                         f"avg={t['avg_latency']:.0f}s"))
    rows.append((
        "fig11/diskann_effect", 0.0,
        f"ragdoll {lat[('flat', 'ragdoll')]:.0f}->"
        f"{lat[('diskann', 'ragdoll')]:.0f}s "
        f"(paper 1236->890) vllm {lat[('flat', 'serial_vllm')]:.0f}->"
        f"{lat[('diskann', 'serial_vllm')]:.0f}s (paper 2331->2427)"))
    return rows
